"""Measurement layer: per-rank step timing (DESIGN_TELEMETRY.md §1).

Two clocks feed a :class:`StepSample`:

* **host wall** — :class:`RankTimer` brackets the jitted step call with
  ``time.perf_counter`` around ``jax.block_until_ready``; this is the
  host's real, dispatch-inclusive step time.
* **per-rank segment clock** — each rank's locally measured matmul-path
  time. The jitted gather built by
  :func:`repro.launch.steps.build_rank_time_gather` all-gathers the
  local clocks over the mesh's ``model`` axis once per control interval,
  so every host sees ALL TP ranks' times — not just its own — without an
  all-reduce every iteration (the paper's passive-refresh discipline,
  Sec. III-A).

On the single-host simulator all "ranks" share one wall clock, so the
per-rank structure of the local clocks comes from the simulated
measurement backend (χ-schedule × :class:`IterationModel` × the ACTIVE
plan's work fraction — i.e. the mitigated runtime a real cluster would
observe). On real heterogeneous hardware the same gather carries
genuinely distinct local measurements; nothing downstream changes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import numpy as np


@dataclasses.dataclass
class StepSample:
    """One control-interval measurement record (the trace unit).

    rank_times are the times AS MEASURED — i.e. under the plan that was
    active for the step (mitigated). ``work_frac`` records that plan's
    retained-work fraction so the estimator (and trace replay) can
    reconstruct full-workload-equivalent times exactly.
    """

    step: int
    rank_times: np.ndarray               # [e] measured per-rank seconds
    plan_signature: str = ""             # canonical static-plan signature
    work_frac: Optional[np.ndarray] = None   # [e] retained-work fraction
    wall_s: float = 0.0                  # host wall around block_until_ready

    def to_json(self) -> Dict[str, Any]:
        d = {"kind": "sample", "step": int(self.step),
             "rank_times": [float(t) for t in np.asarray(self.rank_times)],
             "plan_signature": self.plan_signature,
             "wall_s": float(self.wall_s)}
        if self.work_frac is not None:
            d["work_frac"] = [float(f) for f in np.asarray(self.work_frac)]
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "StepSample":
        wf = d.get("work_frac")
        return StepSample(
            step=int(d["step"]),
            rank_times=np.asarray(d["rank_times"], np.float64),
            plan_signature=d.get("plan_signature", ""),
            work_frac=(np.asarray(wf, np.float64) if wf is not None else None),
            wall_s=float(d.get("wall_s", 0.0)))


class RankTimer:
    """Host wall-clock + per-rank gather for the measurement loop.

    ``start``/``stop`` measure the real step wall (``stop`` blocks on the
    step outputs first, so async dispatch cannot hide device time).
    ``gather`` pushes a per-rank local-clock vector through the jitted
    all-gather — run every ``interval`` steps by ``maybe_gather`` so the
    collective stays off the per-iteration critical path.
    """

    def __init__(self, mesh=None, axis: str = "model", interval: int = 1):
        self.mesh = mesh
        self.axis = axis
        self.interval = max(int(interval), 1)
        self._gather_fn = None
        self._t0: Optional[float] = None
        self.gather_count = 0

    # -- host wall ---------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self, outputs=None) -> float:
        """Block on ``outputs`` (if given) and return elapsed seconds."""
        if outputs is not None:
            import jax
            jax.block_until_ready(outputs)
        t0 = self._t0 if self._t0 is not None else time.perf_counter()
        self._t0 = None
        return time.perf_counter() - t0

    # -- per-rank gather ----------------------------------------------------
    def _gather(self):
        if self._gather_fn is None:
            from repro.launch.steps import build_rank_time_gather
            self._gather_fn = build_rank_time_gather(self.mesh, self.axis)
        return self._gather_fn

    def gather(self, local_times: np.ndarray) -> np.ndarray:
        """All-gather per-rank local clocks; returns the replicated [e]
        vector every host ends up holding."""
        if self.mesh is None or self.mesh.shape.get(self.axis, 1) <= 0:
            return np.asarray(local_times, np.float64)
        self.gather_count += 1
        out = self._gather()(np.asarray(local_times, np.float32))
        return np.asarray(out, np.float64)

    def maybe_gather(self, step: int, local_times: np.ndarray) -> np.ndarray:
        """Gather on control-interval boundaries; pass through otherwise."""
        if self.mesh is not None and step % self.interval == 0:
            return self.gather(local_times)
        return np.asarray(local_times, np.float64)


MEASURE_STREAM = 0x7E1E    # SeedSequence domain tag for measurement noise


def measurement_rng(seed: int) -> np.random.Generator:
    """Noise stream for simulated measurements, keyed off the run seed on
    its own SeedSequence domain so it never aliases the data or
    χ-schedule RNG streams."""
    return np.random.default_rng(
        np.random.SeedSequence((int(seed), MEASURE_STREAM)))


def capture_sample(model, chis, work_frac, *, step: int, plan=None,
                   wall: float = 0.0, rng=None, noise: float = 0.0,
                   timer: Optional[RankTimer] = None) -> StepSample:
    """Simulated-measurement backend SHARED by the train and serve
    drivers (so their trace/estimation semantics cannot diverge): what
    each rank would locally observe for this step — per-rank times under
    the ACTIVE plan (mitigated), optional multiplicative measurement
    noise — gathered across ranks once per control interval when a
    ``timer`` is supplied. Pass a timer only when the measurement vector
    is rank-aligned with its mesh axis (sim_ranks == real tp)."""
    meas = model.times(np.asarray(chis, np.float64),
                       np.asarray(work_frac, np.float64))
    if noise and rng is not None:
        meas = meas * (1.0 + rng.uniform(-noise, noise, len(meas)))
    if timer is not None:
        meas = timer.maybe_gather(step, meas)
    return StepSample(
        step=step, rank_times=meas,
        plan_signature=(plan.static.signature_str()
                        if plan is not None else ""),
        work_frac=np.asarray(work_frac, np.float64).copy(), wall_s=wall)
