"""AdamW with warmup-cosine schedule, global-norm clipping, sharded states.

Pure-pytree implementation (no optax dependency in the container). The
optimizer state mirrors the parameter pytree, so parameter shardings apply
directly to the moments — under tensor parallelism the moments of a
column-split weight are column-split too (ZeRO-1-like for the TP axis).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any            # first moment pytree
    nu: Any            # second moment pytree


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def lr_at(step, cfg: TrainConfig, total_steps: int = 0):
    base = cfg.learning_rate
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    if total_steps > cfg.warmup_steps:
        prog = jnp.clip((step - cfg.warmup_steps)
                        / max(total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    else:
        cos = 1.0
    return base * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.asarray(0.0)
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def apply(params, grads, state: AdamWState, cfg: TrainConfig,
          total_steps: int = 0):
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    lr = lr_at(state.step, cfg, total_steps)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
