"""repro: heterogeneous tensor parallelism with flexible workload control.

Implements Wang et al. (CS.DC 2024): ZERO-resizing, lightweight
broadcast-reduce migration with reduce-merging, and the SEMI-migration
hybrid controller — inside a multi-pod JAX training/serving framework.
"""
__version__ = "1.0.0"
