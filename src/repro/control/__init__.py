"""Unified workload-control plane (DESIGN_CONTROL.md).

One implementation of plan assembly, signature-keyed compile caching,
mitigation dispatch (resize + multi-source migration, projected onto the
real mesh) and control telemetry, shared by the train and serve drivers.
"""
from repro.control.config import ControlConfig  # noqa: F401
from repro.control.plane import ControlPlane, make_schedule  # noqa: F401
from repro.control.projection import ProjectedPlan, project_plan  # noqa: F401
from repro.control.scopes import (  # noqa: F401
    SCOPE_LAYOUT, control_block_size, control_scopes, per_rank_pri,
    plan_pri_arrays, plan_specs, scope_block_table)

__all__ = [
    "ControlConfig", "ControlPlane", "make_schedule", "ProjectedPlan",
    "project_plan",
    "SCOPE_LAYOUT", "control_block_size", "control_scopes", "per_rank_pri",
    "plan_pri_arrays", "plan_specs", "scope_block_table",
]
