"""Sim-scale → real-mesh plan projection (control plane, part 2).

The latency model can simulate a TP group larger than the real host mesh
(``sim_ranks`` > ``tp`` — the paper's V-A setup, where heterogeneity is a
simulation layered over homogeneous hardware). The controller then plans
at sim scale, but the compiled step executes on the real mesh, so the plan
must be projected:

* **resize buckets** keep the previous critical-path semantics: a
  bulk-synchronous group runs at the pace of its slowest rank, so every
  real rank executes the straggler's γ-bucket branch (the modeled latency
  is computed at sim scale regardless — the real program only determines
  *output values*).
* **migration slots** — previously dropped entirely ("migration needs
  sim == real") — now FOLD onto the real mesh: real rank ``s % tp`` stands
  in for sim source ``s``. Folded sources must be distinct (a real rank
  executes one source slot; collisions keep the heaviest shed, canonical
  order) and at least one real helper must remain. Shed counts are clamped
  to the real shard so each folded source keeps >= 1 local block.

Because migration is lossless for ANY source assignment (the helpers
compute exactly the shed blocks from broadcast weights and reduce-merge),
a pure-migration sim plan projects to a pure-migration real plan with
identical outputs — which is what makes serve-time SEMI token-exact even
when the simulated group is larger than the host mesh.

With ``sim_ranks == tp`` the projection is the identity.

Ragged shard geometry (core/geometry.py) composes with the identity
projection only — the control plane enforces ``sim_ranks == tp`` when a
geometry is set. The caller then passes ``real_nb = min(geometry)``: any
rank can be retargeted as a migration source dynamically, so the clamp
must leave the SMALLEST rank a real block. Lossless-ness is unchanged —
helpers compute exactly the shed blocks from broadcast weights, and under
a geometry those are the source's real (non-padding) blocks because every
keep count and shed is quantized against the source's own block count.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.workload import WorkloadPlan


@dataclasses.dataclass(frozen=True)
class ProjectedPlan:
    """A plan's execution arrays mapped onto the real mesh."""

    bucket_by_rank: np.ndarray        # [tp] int32
    mig_srcs: Tuple[int, ...]         # real source ranks, aligned with sheds
    mig_sheds: Tuple[int, ...]        # per-source shed counts (static)
    folded: bool                      # True when sim_ranks != tp

    @property
    def migrating(self) -> bool:
        return bool(self.mig_sheds)


def project_plan(plan: WorkloadPlan, *, sim_ranks: int, tp: int,
                 real_nb: int = 0) -> ProjectedPlan:
    """Project a (possibly sim-scale) plan onto the real ``tp``-rank mesh.

    ``real_nb`` is the real per-rank block count of the migration scope
    (ffn); sheds are clamped to ``real_nb - 1`` so a source always keeps a
    local block. 0 skips the clamp (caller guarantees fit)."""
    sheds_in = plan.static.mig_sheds
    srcs_in = (plan.dynamic.mig_srcs(len(sheds_in)) if sheds_in
               else np.zeros((0,), np.int32))

    if sim_ranks == tp:
        srcs, sheds = [], []
        for s, m in zip(srcs_in, sheds_in):
            m = _clamp_shed(int(m), real_nb)
            if m > 0:
                srcs.append(int(s))
                sheds.append(m)
        return ProjectedPlan(
            bucket_by_rank=np.asarray(plan.dynamic.bucket_by_rank,
                                      np.int32).copy(),
            mig_srcs=tuple(srcs), mig_sheds=tuple(sheds), folded=False)

    # -- folded: real rank r stands in for sim ranks {r, r+tp, ...} -------
    # critical-path buckets: the slowest sim rank's branch everywhere
    buckets = np.asarray(plan.dynamic.bucket_by_rank, np.int32)
    bucket_real = np.full((tp,), int(buckets.max()) if buckets.size else 0,
                          np.int32)

    srcs, sheds = [], []
    if tp > 1:
        taken = set()
        for s, m in zip(srcs_in, sheds_in):     # canonical shed-desc order
            if int(s) < 0:
                continue
            r = int(s) % tp
            if r in taken:
                continue                         # collision: heaviest wins
            m = _clamp_shed(int(m), real_nb)
            if m <= 0:
                continue
            taken.add(r)
            srcs.append(r)
            sheds.append(m)
            if len(taken) >= tp - 1:             # keep >= 1 real helper
                break
    return ProjectedPlan(bucket_by_rank=bucket_real, mig_srcs=tuple(srcs),
                         mig_sheds=tuple(sheds), folded=True)


def _clamp_shed(m: int, real_nb: int) -> int:
    """Clamp a shed count so the source keeps >= 1 real local block."""
    if real_nb > 0:
        return min(m, real_nb - 1)
    return m
