"""The unified control plane shared by the train and serve drivers.

Before this module existed, ``launch/train.py`` and ``launch/serve.py``
each carried their own copy of the same loop: build the static plan
skeleton, key executables on the plan signature in a
:class:`PlanCompileCache`, instantiate the :class:`SemiController` and the
telemetry stack (estimator / timer / trace writer), and — every iteration
— turn the controller's :class:`WorkloadPlan` into the device arrays +
compiled step the runtime actually executes. The two copies had already
diverged once (the serve copy dropped migration whenever the simulated
rank group differed from the real mesh); this class is the single
implementation both drivers now share.

Responsibilities:

* **plan skeleton** — derive the real-mesh :class:`PlanStatic` from a
  :class:`WorkloadControlConfig` and null it when the architecture has no
  prunable scope at this TP degree.
* **compile cache** — own the signature-keyed executable cache; the
  caller supplies a ``builder(static_or_none) -> (step_fn, n_slots,
  aux)`` and the plane guarantees each canonical signature builds once.
* **controller** — the sim-scale :class:`SemiController` (Eq. 1–3), fed
  either the χ-oracle or the closed telemetry loop (``times=measured``).
* **dispatch** — :meth:`dispatch` projects a (possibly sim-scale) plan
  onto the real mesh (:func:`repro.control.projection.project_plan` —
  resize buckets *and* multi-source migration slots, the full SEMI
  mitigation space), picks the executable for the projected signature,
  and assembles the dynamic plan arrays.
* **telemetry** — measurement capture, online estimation, and replayable
  trace output, identical for both drivers.
* **checkpoint** — :meth:`state_arrays` / :meth:`state_meta` /
  :meth:`load_state` round-trip the controller's T_avg bookkeeping,
  priority statistics, estimator window and every host RNG stream, so a
  crash-interrupted run resumes with the exact control trajectory of an
  uninterrupted one (see DESIGN_CONTROL.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence

import numpy as np

import jax.numpy as jnp

from repro.config import ModelConfig, WorkloadControlConfig
from repro.control import scopes as scopes_lib
from repro.control.projection import project_plan
from repro.core import hetero as hetero_lib
from repro.core.controller import SemiController, work_fraction
from repro.core.workload import PlanCompileCache, PlanStatic, WorkloadPlan
from repro.telemetry import (EstimatorConfig, RankTimer, StragglerEstimator,
                             TraceWriter, capture_sample, measurement_rng,
                             schedule_from_trace)


def make_schedule(kind: str, num_ranks: int, *, chi: float = 2.0,
                  period: int = 10, contention_p: float = 0.15,
                  seed: int = 0, trace_in: Optional[str] = None,
                  trace_rank_offset: int = 0):
    """χ-schedule factory shared by the drivers (``None`` = homogeneous).

    ``kind="trace"`` replays a recorded telemetry trace (``trace_in``;
    ``trace_rank_offset`` selects a lane slice of a wider cluster
    trace); the other kinds are the paper's Sec. V-A simulation regimes.
    """
    if kind == "trace":
        if not trace_in:
            raise ValueError("hetero kind 'trace' needs trace_in "
                             "(a telemetry trace to replay)")
        return schedule_from_trace(trace_in, num_ranks=num_ranks,
                                   rank_offset=trace_rank_offset)
    if kind == "none":
        return None
    return hetero_lib.HeteroSchedule(
        num_ranks=num_ranks, kind=kind,
        chis=(chi,) if kind in ("static", "round_robin") else (),
        period=period, contention_p=contention_p, contention_chi=chi,
        seed=seed)


@dataclasses.dataclass(frozen=True)
class PlanCapacity:
    """Plan-adjusted capacity snapshot the cluster router consumes.

    ``step_time_s`` is the modeled bulk-synchronous step time at this
    step's χ feed under the ACTIVE plan's retained-work fractions — the
    inner SEMI loop's mitigation is already priced in, so the outer
    (routing) loop sees what the replica can actually sustain, not its
    raw heterogeneity. ``dense_step_time_s`` is the homogeneous (χ=1,
    neutral-plan) floor for normalization.
    """

    step: int
    chi: np.ndarray
    work_frac: np.ndarray
    step_time_s: float
    dense_step_time_s: float

    @property
    def effective_speed(self) -> float:
        """Fraction of homogeneous throughput this replica sustains
        under its active plan (1.0 = uncontended-equivalent)."""
        return self.dense_step_time_s / max(self.step_time_s, 1e-12)


class ControlPlane:
    """Plan assembly, compile caching, mitigation dispatch and telemetry.

    ``builder(static_or_none)`` must return ``(step_fn, n_plan_slots,
    aux)`` — the jitted executable for that plan signature, the number of
    migration-source slots its plan input carries, and any caller-private
    extra (the drivers stash input shardings there).

    ``controller_blocks`` picks the block-count convention the controller
    reasons in: ``"global"`` (whole-scope blocks, the training drivers'
    historical convention — its pinned trajectories depend on it) or
    ``"local"`` (per-rank shard blocks — the paper's L_i, which sizes
    migration sheds so they actually fit a source's local shard; the
    serve engine uses this).

    ``clamp_sheds`` additionally clamps projected shed counts to the real
    FFN shard (source keeps >= 1 block). The serve engine enables it —
    plans there may be sized at sim scale; the trainer keeps the loud
    ValueError contract of its ``--mig-blocks`` cap instead.
    """

    def __init__(self, model_cfg: ModelConfig, wc: WorkloadControlConfig, *,
                 mesh, tp: int,
                 builder: Callable[[Optional[PlanStatic]], Any],
                 it_model: hetero_lib.IterationModel,
                 sim_ranks: int = 0, controller_blocks: str = "local",
                 clamp_sheds: bool = False,
                 hetero_kind: str = "none", chi: float = 2.0,
                 period: int = 10, contention_p: float = 0.15,
                 seed: int = 0, trace_in: Optional[str] = None,
                 trace_rank_offset: int = 0,
                 trace_out: Optional[str] = None,
                 trace_meta: Optional[Dict[str, Any]] = None,
                 measure_noise: float = 0.0,
                 geometry: Optional[Sequence[int]] = None):
        self.wc = wc
        self.tp = tp
        self.mesh = mesh
        self.it_model = it_model
        self.sim_ranks = sim_ranks or tp
        self.clamp_sheds = clamp_sheds
        self.measure_noise = measure_noise

        # -- static ragged shard geometry (core/geometry.py) ---------------
        # per-rank FFN block counts; an all-equal tuple IS the implicit
        # split and normalizes away, keeping equal-geometry runs on the
        # byte-identical baseline path.
        geo = tuple(int(s) for s in geometry) if geometry else ()
        if len(set(geo)) <= 1:
            geo = ()
        if geo:
            if len(geo) != tp:
                raise ValueError(
                    f"geometry {geo} has {len(geo)} ranks but tp={tp}")
            if self.sim_ranks != tp:
                raise ValueError(
                    "ragged geometry requires the controller to plan at "
                    f"real mesh scale (sim_ranks={self.sim_ranks} != "
                    f"tp={tp})")
        self.geometry = geo

        # -- plan skeleton (real mesh scale) -------------------------------
        static = None
        if wc.enabled:
            static = PlanStatic(
                buckets=wc.gamma_buckets, block_size=wc.block_size,
                tp_size=tp, imputation=wc.imputation, geometry=geo)
            if not scopes_lib.control_scopes(model_cfg, static):
                static = None               # arch exempt at this tp
        self.static = static
        self.scopes = (scopes_lib.control_scopes(model_cfg, static)
                       if static is not None else {})
        if geo and static is not None:
            nb_pad = self.scopes.get("ffn", 0)
            if nb_pad != max(geo):
                raise ValueError(
                    f"geometry {geo}: padded local FFN block count "
                    f"{nb_pad} != max(geometry) — the model config must "
                    "carry the padded d_ff (core/geometry.py "
                    "apply_geometry_cfg)")
        elif geo and wc.enabled:
            raise ValueError(
                "ragged geometry needs the FFN controlled scope, but this "
                "architecture is exempt at this TP degree")
        self.identity_pri = (scopes_lib.plan_pri_arrays(
            self.scopes, {}, tp, geometry=geo or None)
            if static is not None else {})

        # -- executable cache ----------------------------------------------
        self.cache = PlanCompileCache(builder)
        self.base = self.cache.get(static)

        # -- controller at the simulated group scale -----------------------
        if static is not None and self.sim_ranks != tp:
            sim_static = dataclasses.replace(static, tp_size=self.sim_ranks)
            sim_scopes = scopes_lib.control_scopes(model_cfg, sim_static)
        else:
            sim_scopes = self.scopes
        self.sim_nb = next(iter(sim_scopes.values()), 1)
        self.controller: Optional[SemiController] = None
        if wc.enabled and static is not None:
            if geo:
                # geometry mode: the controller reasons in per-rank local
                # blocks (L_i = geometry[i]) regardless of the configured
                # convention — sheds must fit a source's REAL blocks
                n_blocks = int(round(float(np.mean(geo))))
                self.controller = SemiController(
                    wc, self.sim_ranks, it_model, n_blocks, seed=seed,
                    workloads=np.asarray(geo, np.float64))
            else:
                n_blocks = (self.sim_nb * self.sim_ranks
                            if controller_blocks == "global" else self.sim_nb)
                self.controller = SemiController(wc, self.sim_ranks,
                                                 it_model, n_blocks,
                                                 seed=seed)

        # -- χ schedule + telemetry ----------------------------------------
        self.schedule = make_schedule(
            hetero_kind, self.sim_ranks, chi=chi, period=period,
            contention_p=contention_p, seed=seed, trace_in=trace_in,
            trace_rank_offset=trace_rank_offset)
        measured = self.controller is not None and wc.times == "measured"
        self.estimator = (StragglerEstimator(
            it_model, self.sim_ranks, EstimatorConfig.from_control(wc))
            if measured else None)
        self.timer = RankTimer(mesh=mesh if tp > 1 else None,
                               interval=wc.measure_interval)
        self.writer = (TraceWriter(
            trace_out, self.sim_ranks,
            matmul_time=it_model.matmul_time,
            other_time=it_model.other_time, meta=trace_meta or {})
            if trace_out else None)
        self.measure_rng = measurement_rng(seed)
        # retained-work fractions of the last DISPATCHED plan (None until
        # the first controlled step) — what `capacity` prices against
        self._active_frac: Optional[np.ndarray] = None

    # -- per-iteration loop ---------------------------------------------------
    def chis(self, step: int) -> np.ndarray:
        """Simulated per-rank χ for this step (ones when homogeneous)."""
        if self.schedule is not None:
            return self.schedule.chi(step)
        return np.ones((self.sim_ranks,))

    def _geometry_base_frac(self) -> Optional[np.ndarray]:
        """Per-rank STATIC workload fractions L_i/L_eq, or None when the
        split is equal (keeps the geometry-free code path untouched)."""
        if not self.geometry:
            return None
        L = np.asarray(self.geometry, np.float64)
        return L / max(float(L.mean()), 1e-12)

    def controller_times(self, chis: np.ndarray) -> np.ndarray:
        """Per-rank FULL-workload-equivalent times for the controller.

        Measured mode consumes the estimator's reconstruction (neutral
        nominal times until the warmup gate opens); modeled mode reads the
        χ-oracle through the iteration model — Eq.(1) measures the
        heterogeneity degree, never the already-mitigated runtime.

        Under a ragged geometry the static split is part of the baseline,
        not something to mitigate: times are evaluated at the geometry's
        own workload fractions (T_i = M·(L_i/L_eq)·χ_i + C), so Eq.(1)
        sees only the RESIDUAL imbalance the static shards didn't absorb
        — a persistent 2× rank with half the blocks reads as on-time.
        """
        base = self._geometry_base_frac()
        if self.estimator is not None:
            if base is None:
                return (self.estimator.full_times() if self.estimator.ready
                        else self.estimator.nominal_times())
            chi_hat = (self.estimator.chi_hat if self.estimator.ready
                       else np.ones(self.sim_ranks))
            return self.it_model.times(chi_hat, base)
        return self.it_model.times(
            np.asarray(chis, np.float64),
            np.ones(self.sim_ranks) if base is None else base)

    def decide(self, times: np.ndarray):
        """Run the controller (Alg. 2) on per-rank times."""
        return self.controller.plan(times)

    def dispatch(self, plan: WorkloadPlan):
        """Executable + dynamic plan arrays for a plan, on the real mesh.

        Projects the (possibly sim-scale) plan onto the real TP group —
        resize buckets and multi-source migration slots both — then picks
        the compiled step for the projected signature and pads the
        dynamic source vector to its slot count.

        Returns ``(step_fn, plan_arrays, projected)`` — ``projected`` is
        the :class:`ProjectedPlan` that actually EXECUTES (drivers report
        it, not the sim-scale plan, as the migration ground truth).
        """
        # under a ragged geometry the clamp is against the SMALLEST rank's
        # real blocks — any rank can be retargeted as a source dynamically
        real_ffn_nb = (min(self.geometry) if self.geometry
                       else self.scopes.get("ffn", 0)) \
            if self.clamp_sheds else 0
        proj = project_plan(plan, sim_ranks=self.sim_ranks, tp=self.tp,
                            real_nb=real_ffn_nb)
        st_iter = dataclasses.replace(self.static, mig_shed=proj.mig_sheds,
                                      mig_blocks=0)
        step_fn, n_slots, _ = self.cache.get(st_iter)
        # learned priority statistics are collected over the PADDED weight
        # layout and don't renumber onto the ragged split — geometry runs
        # keep the canonical (identity) order instead
        use_learned = bool(plan.dynamic.pri_lists) and not self.geometry
        pri = (scopes_lib.plan_pri_arrays(self.scopes,
                                          plan.dynamic.pri_lists, self.tp)
               if use_learned else self.identity_pri)
        srcs = np.full((max(n_slots, 1),), -1, np.int32)
        k = min(len(proj.mig_srcs), srcs.shape[0])
        srcs[:k] = np.asarray(proj.mig_srcs[:k], np.int32)
        arrays = {"bucket_by_rank": jnp.asarray(proj.bucket_by_rank),
                  "mig_src": jnp.asarray(srcs), "pri": pri}
        return step_fn, arrays, proj

    def work_frac(self, plan: WorkloadPlan) -> np.ndarray:
        """Retained-work fraction per simulated rank implied by a plan.

        Also records the vector as the ACTIVE plan for :meth:`capacity`
        (the drivers call this once per dispatched plan)."""
        f = work_fraction(plan, self.sim_nb)
        self._active_frac = np.asarray(f, np.float64)
        return f

    # -- cluster-router feed --------------------------------------------------
    def chi_feed(self, step: int) -> np.ndarray:
        """Per-rank χ the cluster router consumes: the estimator's χ̂
        once the measured loop is locked, else the schedule's oracle for
        this step (ones when homogeneous)."""
        if self.estimator is not None and self.estimator.ready:
            return np.asarray(self.estimator.chi_hat, np.float64)
        return np.asarray(self.chis(step), np.float64)

    def capacity(self, step: int) -> PlanCapacity:
        """Plan-adjusted capacity at this step's χ feed (see
        :class:`PlanCapacity`). Pure — never runs the controller, so the
        router can poll it without perturbing the control trajectory."""
        chi = self.chi_feed(step)
        frac = (self._active_frac if self._active_frac is not None
                else np.ones(self.sim_ranks))
        ones = np.ones_like(chi)
        return PlanCapacity(
            step=step, chi=chi, work_frac=frac,
            step_time_s=self.it_model.step_time(chi, frac),
            dense_step_time_s=self.it_model.step_time(ones, ones))

    def capture(self, chis, work_frac, *, step: int, plan, wall: float):
        """Simulated-measurement capture: feed the estimator + the trace.

        The in-graph rank gather only applies when the measurement vector
        is rank-aligned with the real mesh (sim group == real tp)."""
        if self.estimator is None and self.writer is None:
            return None
        sample = capture_sample(
            self.it_model, chis, work_frac, step=step, plan=plan, wall=wall,
            rng=self.measure_rng, noise=self.measure_noise,
            timer=self.timer if self.sim_ranks == self.tp else None)
        if self.estimator is not None:
            self.estimator.observe(sample)
        if self.writer is not None:
            self.writer.append(sample)
        return sample

    def close(self) -> None:
        """Flush/close the telemetry trace (safe to call repeatedly)."""
        if self.writer is not None:
            self.writer.close()

    def counts(self) -> Dict[str, int]:
        """Compile-cache + estimator telemetry for histories/benchmarks."""
        out = {"plan_compiles": self.cache.compile_count,
               "plan_cache_hits": self.cache.hit_count}
        if self.estimator is not None:
            out["estimator_updates"] = self.estimator.updates
            out["estimator_rejected"] = self.estimator.rejected_total
        return out

    # -- checkpoint / resume --------------------------------------------------
    def state_arrays(self) -> Dict[str, Any]:
        """Numeric control-plane state as a pytree of numpy arrays
        (checkpointed alongside params/opt in the same npz)."""
        out: Dict[str, Any] = {}
        if self.controller is not None:
            c = self.controller.state_arrays()
            if c:
                out["controller"] = c
        if self.estimator is not None:
            out["estimator"] = self.estimator.state_arrays()
        return out

    def state_meta(self) -> Dict[str, Any]:
        """JSON-able control-plane state (host RNG streams: their 128-bit
        PCG64 state words don't fit numpy dtypes)."""
        meta: Dict[str, Any] = {
            "measure_rng": self.measure_rng.bit_generator.state}
        if self.controller is not None:
            meta["controller_rng"] = self.controller.rng.bit_generator.state
        return meta

    def load_state(self, arrays: Optional[Dict[str, Any]],
                   meta: Optional[Dict[str, Any]]) -> None:
        """Restore :meth:`state_arrays` + :meth:`state_meta` output.

        Missing keys keep the fresh-start default (old checkpoints stay
        loadable). The converse — checkpointed state the CURRENT config
        cannot host (e.g. estimator state resumed without
        ``times=measured``) — voids the bit-identical-resume contract,
        so it warns loudly instead of being dropped in silence."""
        import warnings
        arrays = arrays or {}
        meta = meta or {}
        if "controller" in arrays:
            if self.controller is not None:
                self.controller.load_state_arrays(arrays["controller"])
            else:
                warnings.warn(
                    "checkpoint carries controller state but workload "
                    "control is disabled in this run — the control "
                    "trajectory will NOT match the interrupted run",
                    stacklevel=2)
        if "estimator" in arrays:
            if self.estimator is not None:
                self.estimator.load_state_arrays(arrays["estimator"])
            else:
                warnings.warn(
                    "checkpoint carries estimator state but this run is "
                    "not in times='measured' mode — the control "
                    "trajectory will NOT match the interrupted run",
                    stacklevel=2)
        if "measure_rng" in meta:
            self.measure_rng.bit_generator.state = meta["measure_rng"]
        if "controller_rng" in meta:
            if self.controller is not None:
                self.controller.rng.bit_generator.state = \
                    meta["controller_rng"]
            elif "controller" not in arrays:
                warnings.warn(
                    "checkpoint carries controller RNG state but workload "
                    "control is disabled in this run", stacklevel=2)
