"""Shared control-plane configuration (train + serve).

Historically the trainer hand-built a :class:`WorkloadControlConfig` from
its CLI flags while the serve engine kept its own ``ServeControlConfig``
with overlapping-but-renamed knobs. :class:`ControlConfig` collapses the
two: one dataclass carries every knob a driver needs — technique mode,
heterogeneity simulation, telemetry source, and the static ragged shard
geometry — and :meth:`ControlConfig.to_workload` derives the low-level
:class:`WorkloadControlConfig` the plan-assembly layer consumes.

``repro.launch.serve.ServeControlConfig`` remains as a deprecated alias
(it subclasses this and warns on construction).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.config import WorkloadControlConfig

_MODES = ("off", "zero", "mig", "semi")


@dataclasses.dataclass
class ControlConfig:
    """Workload control + straggler-simulation knobs for a driver loop.

    mode "off" runs dense; "zero"/"mig"/"semi" run the controller on
    modeled (or measured — ``times``) per-rank times each control step.
    "semi" emits the paper's full mitigation space: Eq.(3) selects the
    straggler prefix that migrates losslessly (``max_sources`` concurrent
    slots) and the rest ZERO-resizes. ``sim_ranks`` sizes the simulated
    TP group for the latency model (0 = the real ``tp``); when it differs
    the plan is projected (repro.control.projection). ``geometry`` is a
    per-rank FFN block-count tuple (core/geometry.py): static uneven
    sharding the dynamic controller then plans *residually* against.
    """

    mode: str = "off"                  # off | zero | mig | semi
    hetero_kind: str = "none"    # none | static | round_robin | contention | trace
    chi: float = 4.0
    contention_p: float = 0.15
    period: int = 10
    sim_ranks: int = 0                 # 0 => real tp
    block_size: int = 8
    max_sources: int = 3               # migration slots (mig/semi modes)
    shed_cap: int = 0                  # per-source shed-block cap (0 = uncapped)
    beta_policy: str = "lossless"      # lossless | eq2 (semi mission split)
    imputation: str = "zero"           # zero | average | same
    selection: str = "priority"        # random | priority | priority_diff
    straggler_threshold: float = 0.12
    use_kernel: bool = False
    # decode raw-speed knobs (ISSUE 7) — the ONE shared plumbing for the
    # fused decode-attention path (examples + benches + CLI select it
    # here, never via env sniffing). fused_attention works in every mode
    # including "off"; psum_chunks > 1 chunk-splits the controlled-layer
    # epilogue all-reduce; model_decode_overheads prices the decode
    # attention memory term + collective exposure into the engine's
    # latency model (off by default: the classic legs stay bit-stable).
    fused_attention: bool = False
    psum_chunks: int = 1
    model_decode_overheads: bool = False
    seed: int = 0
    peak_flops: float = 5e9            # latency-model calibration (host CPU)
    mfu: float = 1.0
    # telemetry (DESIGN_TELEMETRY.md): controller input source, trace
    # replay (hetero_kind="trace") and replayable trace capture
    times: str = "modeled"             # modeled | measured
    trace_in: Optional[str] = None
    # replay a SLICE of a wider recorded trace: χ lanes [offset,
    # offset + sim_ranks). How one cluster trace feeds R replicas —
    # replica i runs offset = i * ranks_per_replica (repro.cluster).
    trace_rank_offset: int = 0
    trace_out: Optional[str] = None
    measure_noise: float = 0.0
    measure_interval: int = 1
    # static ragged shard geometry: per-rank FFN block counts (None/equal
    # = classic equal split). See core/geometry.py and DESIGN_SHARDING.md.
    geometry: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"mode {self.mode!r} is not one of {_MODES}")
        if self.psum_chunks < 1:
            raise ValueError(
                f"psum_chunks must be >= 1, got {self.psum_chunks}")
        if self.geometry is not None:
            self.geometry = tuple(int(s) for s in self.geometry)
            if any(s < 1 for s in self.geometry):
                raise ValueError(
                    f"geometry {self.geometry} needs >= 1 block per rank")

    def to_workload(self, *, enabled: Optional[bool] = None,
                    migration_sources: Optional[int] = None,
                    ) -> WorkloadControlConfig:
        """Derive the plan-assembly-layer :class:`WorkloadControlConfig`.

        ``enabled`` defaults to ``mode != "off"``; ``migration_sources``
        defaults to ``max_sources`` in the migration-capable modes and 0
        otherwise. Drivers with legacy CLI contracts (the trainer's
        ``--mig-blocks 0 disables migration``) pass explicit overrides.
        """
        if enabled is None:
            enabled = self.mode != "off"
        if migration_sources is None:
            migration_sources = (self.max_sources
                                 if self.mode in ("mig", "semi") else 0)
        return WorkloadControlConfig(
            enabled=enabled,
            mode=self.mode if self.mode != "off" else "zero",
            imputation=self.imputation, selection=self.selection,
            block_size=self.block_size,
            max_migration_sources=migration_sources,
            migration_shed_cap=self.shed_cap,
            beta_policy=self.beta_policy,
            straggler_threshold=self.straggler_threshold,
            use_kernel=self.use_kernel,
            fused_attention=self.fused_attention,
            psum_chunks=self.psum_chunks,
            times=self.times,
            measure_interval=self.measure_interval)
