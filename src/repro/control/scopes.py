"""Prunable-scope discovery and plan-array assembly (control plane, part 1).

The controller reasons about abstract "blocks"; the model exposes concrete
prunable scopes (ffn / qkv / attn_out) whose block counts depend on the
architecture and the TP degree. This module is the single place that maps
between the two — shared by the train and serve drivers (via
:class:`repro.control.ControlPlane`) and by the dry-run/HLO tooling, so
plan assembly cannot silently diverge between entry points.

Moved here from ``repro.launch.steps`` (which re-exports for backwards
compatibility): these helpers are pure plan logic with no step-building in
them, and the unified control plane needs them without importing the
launcher.
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.core.workload import PlanStatic, adapt_block_size

SDS = jax.ShapeDtypeStruct


def _replicated(mesh):
    return NamedSharding(mesh, P())


# scope -> layout of its priority list:
#   "col": contraction replicated across TP -> global pri [nb]
#   "row": contraction TP-sharded          -> per-rank pri [tp, nb]
SCOPE_LAYOUT = {"qkv": "col", "attn_out": "row", "ffn": "row"}


def per_rank_pri(global_pri, e: int, nb_loc: int, geometry=None):
    """Split a GLOBAL keep-first block permutation into per-rank local
    keep-first lists.

    Equal split (geometry None): rank r owns global blocks
    [r·nb_loc, (r+1)·nb_loc) — the helper renumbering is a plain modulo.

    Ragged split (geometry = per-rank block counts, core/geometry.py):
    rank r owns canonical blocks [off_r, off_r + geometry[r]); canonical
    block off_r + j sits in local slot j of the padded layout. Each row
    lists the rank's real blocks in keep-first order first, then its
    padding slot ids [geometry[r], nb_loc) — padding can never be
    selected because every keep count is capped at geometry[r]."""
    if geometry is None:
        out = np.zeros((e, nb_loc), np.int32)
        for r in range(e):
            lo, hi = r * nb_loc, (r + 1) * nb_loc
            mine = [g - lo for g in global_pri if lo <= g < hi]
            out[r] = np.asarray(mine, np.int32)
        return out
    sizes = tuple(int(s) for s in geometry)
    if len(sizes) != e:
        raise ValueError(f"geometry {sizes} has {len(sizes)} ranks, e={e}")
    if max(sizes) != nb_loc:
        raise ValueError(
            f"padded local block count {nb_loc} != max(geometry)={max(sizes)}")
    out = np.zeros((e, nb_loc), np.int32)
    off = 0
    for r, L in enumerate(sizes):
        mine = [g - off for g in global_pri if off <= g < off + L]
        if len(mine) != L:
            raise ValueError(
                f"global pri covers {len(mine)} of rank {r}'s {L} blocks")
        out[r] = np.asarray(mine + list(range(L, nb_loc)), np.int32)
        off += L
    return out


def plan_pri_arrays(scopes: Dict[str, int], pri_lists: Dict[str, Any],
                    tp: int, geometry=None) -> Dict[str, jax.Array]:
    """Device pri arrays for a plan: the controller's keep-first
    permutations where available (split per rank for row scopes),
    identity order otherwise. Shared by the train and serve drivers so
    priority selection cannot silently diverge between them.

    ``geometry`` (per-rank block counts) applies to the "ffn" scope only:
    that is the scope the ragged shard geometry redistributes; attention
    scopes keep the equal split."""
    out = {}
    for name, nb in scopes.items():
        pri = pri_lists.get(name)
        if SCOPE_LAYOUT.get(name, "row") == "col":
            if pri is None or pri.shape[0] != nb:
                pri = jnp.arange(nb, dtype=jnp.int32)
            out[name] = jnp.asarray(pri)
        elif geometry is not None and name == "ffn":
            nb_total = int(sum(geometry))
            if pri is None or pri.shape[0] != nb_total:
                pri = np.arange(nb_total, dtype=np.int32)
            out[name] = jnp.asarray(per_rank_pri(pri, tp, nb, geometry))
        else:
            nb_total = nb * tp
            if pri is None or pri.shape[0] != nb_total:
                pri = np.arange(nb_total, dtype=np.int32)
            out[name] = jnp.asarray(per_rank_pri(pri, tp, nb))
    return out


def plan_specs(static: PlanStatic, cfg: ModelConfig, mesh: Mesh,
               scopes: Dict[str, int]):
    """SDS + shardings for the dynamic plan arrays. scopes: name ->
    num_blocks (layout per SCOPE_LAYOUT; per-layer plans get a leading
    num_layers dim — the PriDiff variant)."""
    e = static.tp_size
    lead = (static.num_layers,) if static.per_layer else ()
    # one slot per concurrent migration source (>=1 so the array shape is
    # stable when migration is off; idle slots carry -1)
    n_slots = max(1, static.num_sources)

    def pri_shape(name, nb):
        core = (nb,) if SCOPE_LAYOUT.get(name) == "col" else (e, nb)
        return SDS(lead + core, jnp.int32)

    specs = {"bucket_by_rank": SDS(lead + (e,), jnp.int32),
             "mig_src": SDS((n_slots,), jnp.int32),
             "pri": {k: pri_shape(k, nb) for k, nb in scopes.items()}}
    shards = {"bucket_by_rank": _replicated(mesh),
              "mig_src": _replicated(mesh),
              "pri": {k: _replicated(mesh) for k in scopes}}
    return specs, shards


def control_scopes(cfg: ModelConfig, static: PlanStatic) -> Dict[str, int]:
    """Prunable scopes and their block counts for this arch at this TP.

    ffn      — intermediate (d_ff/e) blocks, resizing + migration.
    qkv      — d_model contraction blocks of the col-split projections
               (replicated across TP, so divisibility is vs d_model).
    attn_out — per-rank (H·hd/e) contraction blocks of the out projection.
    A scope with no >=32-lane divisor is exempt (DESIGN.md §5/§11)."""
    e = static.tp_size
    scopes: Dict[str, int] = {}
    b_ffn = control_block_size(cfg, static)
    if b_ffn:
        scopes["ffn"] = (_controlled_dff(cfg) // e) // b_ffn
    if cfg.num_heads and cfg.mla is None:
        b_qkv = adapt_block_size(cfg.d_model, static.block_size)
        if b_qkv and cfg.d_model // b_qkv >= 2:
            scopes["qkv"] = cfg.d_model // b_qkv
        attn_loc = (cfg.num_heads * cfg.resolved_head_dim) // e
        b_out = adapt_block_size(attn_loc, static.block_size)
        if b_out and attn_loc // b_out >= 2:
            scopes["attn_out"] = attn_loc // b_out
    return scopes


def scope_block_table(cfg: ModelConfig, static: PlanStatic):
    """Hashable (scope, block) pairs for PlanStatic.scope_blocks."""
    e = static.tp_size
    out = []
    b_ffn = control_block_size(cfg, static)
    if b_ffn:
        out.append(("ffn", b_ffn))
    if cfg.num_heads and cfg.mla is None:
        b_qkv = adapt_block_size(cfg.d_model, static.block_size)
        if b_qkv:
            out.append(("qkv", b_qkv))
        b_out = adapt_block_size((cfg.num_heads * cfg.resolved_head_dim) // e,
                                 static.block_size)
        if b_out:
            out.append(("attn_out", b_out))
    return tuple(out)


def _controlled_dff(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.moe is not None:
        return cfg.moe.num_shared_experts * (cfg.moe.d_shared or cfg.moe.d_expert)
    return cfg.d_ff


def control_block_size(cfg: ModelConfig, static: PlanStatic) -> int:
    """Largest MXU-friendly block dividing the per-rank FFN width, capped
    by the configured preference; 0 => this arch's FFN is exempt at this
    TP degree (recorded per DESIGN.md §5 — e.g. yi-6b's 11008/16 = 688 is
    16·43, below the 32-lane floor)."""
    dff = _controlled_dff(cfg)
    if dff == 0:
        return 0
    loc = dff // static.tp_size
    b = adapt_block_size(loc, static.block_size)
    if b and loc // b >= 2:
        return b
    return 0
