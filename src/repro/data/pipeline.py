"""Synthetic, deterministic, shardable data pipelines.

The container is offline (no CIFAR-10 / text corpora), so we generate
procedural data with enough learnable structure that accuracy *trends*
(the paper's concern — Sec. V-A "we primarily concern the range of
accuracy variation ... instead of the absolute value") are measurable:

* ``TokenTaskStream`` — language-model batches where the next token is a
  deterministic affine function of the previous k tokens (learnable by a
  small transformer; random baseline = 1/vocab accuracy).
* ``PatternImageStream`` — CIFAR-like 32x32x3 images whose class controls
  a spatial frequency/orientation pattern plus noise (learnable by ViT).

Both are iterator-style, seeded, and emit globally-batched numpy arrays
that the launcher shards over the (pod, data) mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenTaskStream:
    """Periodic-copy LM task: each sequence is a random length-`period`
    pattern tiled to seq_len, so the next token equals the token `period`
    positions back — learnable by attention (induction) and by recurrent
    state within tens of steps, with 1/vocab random baseline."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    period: int = 4

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed + 1)
        V, p = self.vocab_size, self.period
        reps = -(-(self.seq_len + 1) // p)
        while True:
            pat = rng.integers(0, V, size=(self.batch_size, p))
            toks = np.tile(pat, (1, reps))[:, : self.seq_len + 1].astype(np.int32)
            yield {
                "tokens": toks[:, :-1],
                "labels": toks[:, 1:],
            }


@dataclasses.dataclass
class PatternImageStream:
    """Class-conditional oriented sinusoid gratings + noise, 32x32x3."""

    num_classes: int = 10
    image_size: int = 32
    batch_size: int = 64
    noise: float = 0.35
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        s = self.image_size
        yy, xx = np.meshgrid(np.arange(s), np.arange(s), indexing="ij")
        while True:
            labels = rng.integers(0, self.num_classes, size=self.batch_size)
            angles = labels * (np.pi / self.num_classes)
            freqs = 2.0 + (labels % 5)
            imgs = np.zeros((self.batch_size, s, s, 3), dtype=np.float32)
            for b in range(self.batch_size):
                phase = rng.uniform(0, 2 * np.pi)
                wave = np.sin(
                    2 * np.pi * freqs[b] / s
                    * (xx * np.cos(angles[b]) + yy * np.sin(angles[b]))
                    + phase)
                for c in range(3):
                    imgs[b, :, :, c] = wave * (0.5 + 0.5 * ((labels[b] >> c) & 1))
            imgs += rng.normal(0, self.noise, size=imgs.shape).astype(np.float32)
            yield {"images": imgs, "labels": labels.astype(np.int32)}


def skip_batches(stream_iter: Iterator[dict], n: int) -> Iterator[dict]:
    """Fast-forward a stream iterator past its first ``n`` batches.

    The streams are seeded and draw a fixed number of RNG variates per
    batch, so discarding ``n`` draws reproduces EXACTLY the generator
    state an uninterrupted run would have after ``n`` batches — this is
    how a resumed trainer re-aligns its data position with the checkpoint
    (cheap: the data is synthetic)."""
    for _ in range(n):
        next(stream_iter)
    return stream_iter


def patchify(images: np.ndarray, patch: int = 4) -> np.ndarray:
    """[B,H,W,C] -> [B, (H/p)*(W/p), p*p*C] patch embedding input."""
    b, h, w, c = images.shape
    gh, gw = h // patch, w // patch
    x = images.reshape(b, gh, patch, gw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(b, gh * gw, patch * patch * c)
    return x


def eval_accuracy(predict_fn, stream_iter, num_batches: int = 8) -> float:
    """Top-1 accuracy of `predict_fn(batch) -> logits` over held-out batches."""
    correct = total = 0
    for _ in range(num_batches):
        batch = next(stream_iter)
        logits = np.asarray(predict_fn(batch))
        pred = logits.argmax(-1)
        labels = batch["labels"]
        correct += (pred == labels).sum()
        total += labels.size
    return float(correct) / float(total)
