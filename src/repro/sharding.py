"""Sharding utilities: logical axis rules, spec builders, constraint helpers.

We follow the MaxText-style pattern: parameters and activations carry
*logical* axis names; a rule table maps logical names to mesh axes. GSPMD
handles non-divisible dimensions by padding (e.g. 28 attention heads on a
16-way model axis), which keeps every assigned architecture lowerable on
the production mesh without per-arch special cases.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axes. "data"-like axes shard the batch; "model"
# shards the tensor-parallel dimension (the paper's `e` parallel tasks).
# The pod axis extends data parallelism across pods.
DEFAULT_RULES: Tuple[Tuple[str, Optional[Tuple[str, ...]]], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),                    # activations: sequence replicated by default
    ("decode_seq", ("data",)),        # KV caches at decode: shard sequence over data
    ("embed", None),                  # d_model replicated
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("head_dim", None),
    ("mlp", ("model",)),              # FFN hidden dim (column-split)
    ("expert", ("model",)),           # expert-parallel
    ("expert_mlp", None),             # per-expert hidden dim
    ("lru", ("model",)),              # RG-LRU / SSM inner width
    ("kv_lora", None),
    ("classes", None),
    ("layers", None),       # stacked-layer dim; ("data",) under FSDP
)


def make_rules(**overrides):
    rules = dict(DEFAULT_RULES)
    rules.update(overrides)
    return tuple(rules.items())


def logical_to_spec(axes: Sequence[Optional[str]], rules=None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    rules = dict(rules or DEFAULT_RULES)
    parts = []
    for ax in axes:
        if ax is None:
            parts.append(None)
            continue
        mesh_axes = rules.get(ax)
        if mesh_axes is None:
            parts.append(None)
        elif len(mesh_axes) == 1:
            parts.append(mesh_axes[0])
        else:
            parts.append(tuple(mesh_axes))
    return P(*parts)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Version-portable ``shard_map``.

    Newer jax exposes ``jax.shard_map`` (with ``check_vma``); the releases
    this repo also supports only ship ``jax.experimental.shard_map``
    (whose equivalent flag is ``check_rep``). All shard_map call sites in
    the repo go through this wrapper.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def shard(x, axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None, rules=None):
    """Apply a logical-axes sharding constraint inside jit.

    No-op when no mesh is active (single-device smoke tests / unit tests).
    Rules resolve as: explicit arg > ambient use_rules() > DEFAULT_RULES.
    """
    mesh = mesh or current_mesh()
    if mesh is None:
        return x
    rules = rules or current_rules()
    spec = filter_spec_for_mesh(logical_to_spec(axes, rules), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


_CURRENT_MESH = [None]
_CURRENT_RULES = [None]


def current_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH[0]


def current_rules():
    return _CURRENT_RULES[0]


class use_rules:
    """Scope logical-axis rule overrides (active during jit tracing)."""

    def __init__(self, rules):
        self.rules = rules
        self._prev = None

    def __enter__(self):
        self._prev = _CURRENT_RULES[0]
        _CURRENT_RULES[0] = self.rules
        return self.rules

    def __exit__(self, *exc):
        _CURRENT_RULES[0] = self._prev
        return False


class use_mesh:
    """Context manager recording the active mesh for `shard` helpers."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self._ctx = None

    def __enter__(self):
        _CURRENT_MESH[0] = self.mesh
        self._ctx = self.mesh
        self.mesh.__enter__()
        return self.mesh

    def __exit__(self, *exc):
        self.mesh.__exit__(*exc)
        _CURRENT_MESH[0] = None
        return False


def named_sharding(mesh: Mesh, *axes: Optional[str], rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, rules))


def filter_spec_for_mesh(spec: P, mesh: Mesh) -> P:
    """Drop mesh-axis references that don't exist in `mesh` (e.g. 'pod' on
    the single-pod mesh)."""
    names = set(mesh.axis_names)

    def _f(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(e for e in entry if e in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*[_f(e) for e in spec])


def fit_spec_to_shape(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (jit argument shardings
    require exact divisibility, unlike internal constraints)."""
    sizes = dict(mesh.shape)

    def _f(entry, dim):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        if n == 0 or dim % n != 0:
            # try progressively shorter prefixes
            for k in range(len(axes) - 1, 0, -1):
                n = 1
                for a in axes[:k]:
                    n *= sizes.get(a, 1)
                if dim % n == 0:
                    return axes[:k] if k > 1 else axes[0]
            return None
        return entry

    entries = list(spec) + [None] * (len(shape) - len(spec))
    return P(*[_f(e, d) for e, d in zip(entries, shape)])


def ragged_local_width(padded_width: int, mesh: Mesh,
                       axis: str = "model") -> int:
    """Per-rank lane count of the padded ragged-FFN layout.

    A ragged shard geometry (core/geometry.py) is realized as zero-padded
    EQUAL GSPMD shards on the "mlp" logical axis — rank r's slice holds
    its geometry[r] real blocks first and inert zero blocks after, so no
    sharding rule changes. This validates that the padded width actually
    equal-splits over the mesh's TP axis and returns the local width."""
    n = int(dict(mesh.shape).get(axis, 1))
    if padded_width % n:
        raise ValueError(
            f"padded FFN width {padded_width} does not equal-split over "
            f"the {n}-way {axis!r} mesh axis — the geometry's padded "
            "layout is malformed")
    return padded_width // n


def param_sharding_tree(abstract_params, mesh: Mesh, logical_axes_tree, rules=None):
    """Build a NamedSharding pytree for params from a logical-axes pytree."""
    def _one(axes):
        spec = filter_spec_for_mesh(logical_to_spec(axes, rules), mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(_one, logical_axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            e is None or isinstance(e, str) for e in x))
