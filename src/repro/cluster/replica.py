"""ReplicaHandle: one :class:`~repro.launch.serve.ServeEngine` behind
the cluster router, with a lifecycle state machine (DESIGN_CLUSTER.md).

States and transitions::

    SPARE ──promote()──> ACTIVE ──begin_drain()──> DRAINING ──(idle)──> DRAINED
                            │                          │
                            └────────fail()────────────┴──> FAILED
    FAILED/DRAINED ──restart()──> ACTIVE        (engine rebuilt from the
                                                 factory, i.e. from the
                                                 latest checkpoint)

A replica is *warm* by construction: the factory builds its engine (and
loads params from the checkpoint directory, the race-tolerant
``checkpoint/store.load_latest_params`` path) at handle creation, so
``promote()`` is O(1) — it only opens admission. ``begin_drain`` and
``fail`` both return the requests the cluster manager must re-route;
greedy decode is deterministic, so a reassigned request regenerates its
exact tokens from scratch on another replica (no drops, no divergence).
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.launch.serve import (Completion, LoadSnapshot, Request,
                                ServeEngine)

# lifecycle states
SPARE = "spare"          # warm, params loaded, not admitting, not ticking work
ACTIVE = "active"        # admitting + serving
DRAINING = "draining"    # not admitting; finishing in-flight slots
DRAINED = "drained"      # drain complete: idle, engine intact
FAILED = "failed"        # engine discarded; incomplete work reassigned

#: states whose engine ticks every cluster step (SPARE ticks idle so its
#: χ-schedule lane stays aligned with the cluster step for when it is
#: promoted mid-run; DRAINED/FAILED replicas are out of the time base)
_TICKING = (SPARE, ACTIVE, DRAINING)


class ReplicaHandle:
    """One serve engine + its lifecycle state, as the router sees it."""

    def __init__(self, name: str,
                 engine_factory: Callable[[], ServeEngine], *,
                 spare: bool = False):
        self.name = name
        self._factory = engine_factory
        self.engine: Optional[ServeEngine] = engine_factory()
        self.state = SPARE if spare else ACTIVE
        self._harvested = 0            # engine.completions consumed so far
        self.restarts = 0

    # -- routing interface ---------------------------------------------------
    @property
    def admitting(self) -> bool:
        return self.state == ACTIVE

    def try_route(self, req: Request) -> bool:
        """Admit a request if this replica is ACTIVE and can take it
        (non-blocking — the engine's ``try_submit`` contract)."""
        if not self.admitting:
            return False
        return self.engine.try_submit(req)

    def snapshot(self) -> LoadSnapshot:
        """The engine's load/capacity snapshot (raises when FAILED)."""
        if self.engine is None:
            raise RuntimeError(f"replica {self.name} is failed — no engine")
        return self.engine.load_snapshot()

    def score(self) -> float:
        """Aggregate effective-throughput score: modeled decode slots per
        second under the replica's ACTIVE plan — num_slots over the
        plan-adjusted step time (StragglerEstimator-fed in measured
        mode). Higher is better; the chi_aware policy uses the full
        snapshot, this scalar is for dashboards/tests."""
        s = self.snapshot()
        return s.num_slots / max(s.step_time_s, 1e-12)

    # -- cluster-step driving ------------------------------------------------
    def tick(self) -> Optional[dict]:
        """One cluster step for this replica (no-op unless ticking).

        A DRAINING replica that has gone idle completes its drain here
        (state -> DRAINED)."""
        if self.state not in _TICKING or self.engine is None:
            return None
        report = self.engine.tick()
        if self.state == DRAINING and self.engine.idle:
            self.state = DRAINED
        return report

    def harvest(self) -> List[Completion]:
        """Completions finished since the last harvest."""
        if self.engine is None:
            return []
        out = self.engine.completions[self._harvested:]
        self._harvested = len(self.engine.completions)
        return out

    # -- lifecycle -----------------------------------------------------------
    def promote(self) -> None:
        """SPARE -> ACTIVE (warm-spare promotion: admission opens; params
        were already loaded at construction)."""
        if self.state != SPARE:
            raise ValueError(
                f"replica {self.name}: promote() from {self.state!r} — "
                "only a SPARE can be promoted")
        self.state = ACTIVE

    def begin_drain(self) -> List[Request]:
        """Stop admitting; in-flight slots finish, queued-but-unadmitted
        requests are returned for reassignment (they haven't started, so
        moving them costs nothing and shortens the drain)."""
        if self.state not in (ACTIVE, DRAINING):
            raise ValueError(
                f"replica {self.name}: begin_drain() from {self.state!r}")
        evicted = self.engine.evict_queue()
        self.state = DRAINED if self.engine.idle else DRAINING
        return evicted

    def fail(self) -> List[Request]:
        """Simulated replica loss: the engine is discarded and every
        INCOMPLETE request — in-flight slots first (admission order),
        then the queue — is returned for reassignment. The caller must
        harvest completions before failing, or finished work is lost."""
        if self.engine is None:
            return []
        inflight = self.engine.active_requests()
        queued = self.engine.evict_queue()
        self.engine.close()
        self.engine = None
        self.state = FAILED
        return inflight + queued

    def restart(self, sync_step: int = 0) -> None:
        """Rebuild the engine from the factory (fresh params from the
        latest checkpoint) and return to ACTIVE. ``sync_step``
        fast-forwards the new engine's step counter so its χ-schedule
        lane stays aligned with the cluster step."""
        if self.state not in (FAILED, DRAINED):
            raise ValueError(
                f"replica {self.name}: restart() from {self.state!r}")
        if self.engine is None:
            self.engine = self._factory()
            self._harvested = 0
        self.engine.step_count = max(self.engine.step_count, int(sync_step))
        self.state = ACTIVE
        self.restarts += 1

    def close(self) -> None:
        if self.engine is not None:
            self.engine.close()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"ReplicaHandle({self.name!r}, state={self.state!r})"


# ---------------------------------------------------------------------------
# static-analysis registration (repro.analysis; see DESIGN_ANALYSIS.md)
# ---------------------------------------------------------------------------

from repro.analysis import registry as _analysis  # noqa: E402


def _an_cluster_tick_cases(env):
    """The jitted artifact a cluster tick drives IS its replica engine's
    base serve step — trace it through the ReplicaHandle path so a
    future replica-specific step wrapper can't dodge the audit. Jaxpr
    only (the serve_engine_step provider already compiles + alias-checks
    the same executable; donation here is checked by declaration)."""
    if not env.heavy:
        return []
    handle = ReplicaHandle(
        "analysis", lambda: ServeEngine("yi-6b", num_slots=2, max_len=8))
    try:
        return handle.engine.analysis_cases("cluster_tick",
                                            compile_hlo=False)
    finally:
        handle.close()


_analysis.register("cluster_tick", _an_cluster_tick_cases)
