"""Multi-replica serving cluster (DESIGN_CLUSTER.md).

The paper's SEMI loop balances work *within* one TP group; this package
adds the loop *around* it — straggler-aware routing across R serve
replicas, sharing the same χ/plan/capacity telemetry vocabulary:

* :class:`ReplicaHandle` — one ServeEngine + lifecycle state machine
  (SPARE / ACTIVE / DRAINING / DRAINED / FAILED).
* :class:`Router` — pluggable policies: ``round_robin``,
  ``least_queue``, and the headline ``chi_aware`` (prices a request
  against each replica's plan-adjusted residual capacity).
* :class:`ReplicaManager` — lockstep cluster driver: deterministic
  tick interleaving, drain + warm-spare promotion, fail + zero-drop
  request reassignment, one replayable R·W-lane cluster trace.
"""
from repro.cluster.manager import ReplicaManager
from repro.cluster.replica import (ACTIVE, DRAINED, DRAINING, FAILED, SPARE,
                                   ReplicaHandle)
from repro.cluster.router import POLICIES, Router, chi_aware_cost

__all__ = [
    "ReplicaHandle", "ReplicaManager", "Router", "POLICIES",
    "chi_aware_cost", "SPARE", "ACTIVE", "DRAINING", "DRAINED", "FAILED",
]
