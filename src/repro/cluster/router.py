"""Request routing across serve replicas (DESIGN_CLUSTER.md §2).

The router is the OUTER control loop of the nested pair: inside each
replica the SEMI controller migrates/resizes work across TP ranks every
step (paper Eq. 1–3); across replicas the router steers whole requests.
Both loops speak the same telemetry vocabulary — the
:class:`~repro.launch.serve.LoadSnapshot` a replica exposes carries its
χ feed, its ACTIVE plan's retained-work fractions, and the resulting
modeled step time (``ControlPlane.capacity``), so the router prices a
replica at its capacity *after* intra-replica mitigation. A straggling
replica whose SEMI loop already absorbed the imbalance reads near-dense;
only the residual slowdown the inner loop could not hide leaks into the
routing cost.

Policies (pluggable; a policy is a pure ranking function):

* ``round_robin``   — rotate over admitting replicas; load-blind.
* ``least_queue``   — fewest waiting+active requests; χ-blind.
* ``chi_aware``     — the headline policy: estimated completion time of
  the request on each replica, ``step_time_s * (backlog_steps +
  request_cost) / num_slots`` — the replica's plan-adjusted step time
  times the slot-steps ahead of (and including) the request, amortized
  over its decode slots. Deterministic: ties break to the lowest
  replica index.

``route`` walks the ranking and admits on the first replica whose
non-blocking ``try_submit`` accepts — a full/bounded queue falls through
to the next-best replica instead of dropping the request.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

from repro.cluster.replica import ReplicaHandle
from repro.launch.serve import LoadSnapshot, Request

POLICIES = ("round_robin", "least_queue", "chi_aware")

#: a candidate is (index in the manager's replica list, handle, snapshot)
Candidate = Tuple[int, ReplicaHandle, LoadSnapshot]
#: policy: (request, candidates) -> candidates ranked best-first
Policy = Callable[[Request, List[Candidate]], List[Candidate]]


def _cost_steps(handle: ReplicaHandle, req: Request) -> int:
    return handle.engine.request_cost_steps(len(req.prompt),
                                            req.max_new_tokens)


def chi_aware_cost(req: Request, cand: Candidate) -> float:
    """Modeled seconds until this replica finishes the request: every
    slot-step owed (its backlog plus this request) priced at the
    replica's plan-adjusted step time, spread over its decode slots."""
    _, handle, snap = cand
    owed = snap.backlog_steps + _cost_steps(handle, req)
    return snap.step_time_s * owed / max(snap.num_slots, 1)


class Router:
    """Pluggable request router over a replica set.

    ``policy`` is one of :data:`POLICIES` or a custom callable
    ``(request, candidates) -> ranked candidates``. The router holds the
    only routing state (the round-robin cursor); everything else reads
    fresh snapshots per decision, so lifecycle changes (drain, fail,
    promote) take effect on the very next request.
    """

    def __init__(self, policy="chi_aware"):
        if callable(policy):
            self.policy_name = getattr(policy, "__name__", "custom")
            self._rank = policy
        else:
            if policy not in POLICIES:
                raise ValueError(
                    f"unknown routing policy {policy!r}; pick one of "
                    f"{POLICIES} or pass a callable")
            self.policy_name = policy
            self._rank = getattr(self, f"_rank_{policy}")
        self._rr = 0                      # round-robin cursor
        self.decisions = 0

    # -- built-in policies (pure rankings, best first) -----------------------
    def _rank_round_robin(self, req: Request,
                          cands: List[Candidate]) -> List[Candidate]:
        k = self._rr % len(cands)
        return cands[k:] + cands[:k]

    def _rank_least_queue(self, req: Request,
                          cands: List[Candidate]) -> List[Candidate]:
        return sorted(cands, key=lambda c: (c[2].queue_depth + c[2].active,
                                            c[0]))

    def _rank_chi_aware(self, req: Request,
                        cands: List[Candidate]) -> List[Candidate]:
        return sorted(cands, key=lambda c: (chi_aware_cost(req, c), c[0]))

    # -- routing -------------------------------------------------------------
    def rank(self, req: Request,
             replicas: Sequence[ReplicaHandle]) -> List[Candidate]:
        """Admitting replicas ranked best-first under the policy."""
        cands = [(i, h, h.snapshot()) for i, h in enumerate(replicas)
                 if h.admitting]
        if not cands:
            return []
        return self._rank(req, cands)

    def route(self, req: Request,
              replicas: Sequence[ReplicaHandle]) -> Optional[ReplicaHandle]:
        """Admit ``req`` on the best replica that will take it.

        Walks the ranking so a refused admission (bounded queue at
        capacity, request too large for that engine) falls through to
        the next-best replica. Returns the admitting handle, or ``None``
        when no replica can take the request right now (the manager
        retries it next cluster step)."""
        ranked = self.rank(req, replicas)
        for _, handle, _ in ranked:
            if handle.try_route(req):
                self.decisions += 1
                if self.policy_name == "round_robin":
                    self._rr += 1
                return handle
        return None
