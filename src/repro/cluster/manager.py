"""ReplicaManager: lockstep multi-replica serving with lifecycle +
zero-drop reassignment (DESIGN_CLUSTER.md §3).

One host loop drives R serve engines deterministically: each *cluster
step* routes newly eligible requests, then ticks every replica exactly
once (``ServeEngine.tick`` — a replica with nothing admissible takes an
idle tick that advances its step counter but not its modeled clock, so
χ-schedule lanes stay aligned with the cluster step across replicas
while latencies remain honest durations).

Zero-drop invariant: every submitted request produces exactly one
completion, token-exact regardless of drains, failures, or promotions.
It holds because

* drain/fail return the incomplete requests (queued, and for fail also
  in-flight) and the manager re-routes them with priority over fresh
  arrivals;
* completed uids are excluded from fail-reassignment, and harvest
  dedupes by uid (``duplicate_completions`` counts violations — pinned
  to zero by tests);
* greedy decode is deterministic, so a request re-run from scratch on
  another replica regenerates its exact tokens.

``record_trace`` writes ONE R·W-lane telemetry JSONL for the whole
cluster (header tagged ``{"replicas": R, "ranks_per_replica": W}``), so
a cluster run replays from one trace set via
:func:`repro.telemetry.replica_schedules`.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.cluster.replica import ACTIVE, DRAINING, SPARE, ReplicaHandle
from repro.cluster.router import Router
from repro.launch.serve import (Completion, Request, latency_percentiles)
from repro.telemetry import StepSample, TraceWriter


class ReplicaManager:
    """Cluster of :class:`ReplicaHandle`\\ s behind one :class:`Router`."""

    def __init__(self, replicas: Sequence[ReplicaHandle],
                 router: Optional[Router] = None, *,
                 record_trace: Optional[str] = None):
        names = [h.name for h in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate replica names: {names}")
        self.replicas: List[ReplicaHandle] = list(replicas)
        self.by_name: Dict[str, ReplicaHandle] = {h.name: h
                                                  for h in self.replicas}
        self.router = router or Router()
        self.cluster_step = 0
        # cluster-level admission: `pending` holds not-yet-eligible /
        # not-yet-routable fresh arrivals (FIFO by arrival step);
        # `_reassign` holds drain/fail evictees, served FIRST — they
        # already waited once.
        self.pending: collections.deque = collections.deque()
        self._reassign: collections.deque = collections.deque()
        self.completions: Dict[int, Completion] = {}
        self.owner: Dict[int, str] = {}       # uid -> completing replica
        self.routed_to: Dict[int, str] = {}   # uid -> last routed replica
        self.duplicate_completions = 0
        self.reassigned = 0
        self.events: List[dict] = []
        self._writer: Optional[TraceWriter] = None
        self._trace_path = record_trace

    # -- submission ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request at cluster level; it is routed once its
        ``arrival_step`` (in CLUSTER steps) has passed and a replica can
        take it."""
        self.pending.append(req)

    def _route_one(self, req: Request) -> bool:
        handle = self.router.route(req, self.replicas)
        if handle is None:
            return False
        self.routed_to[req.uid] = handle.name
        self.events.append({"step": self.cluster_step, "kind": "route",
                            "uid": req.uid, "replica": handle.name,
                            "policy": self.router.policy_name})
        return True

    # -- the lockstep cluster step -------------------------------------------
    def step(self) -> None:
        """One cluster step: route, tick every replica once, harvest."""
        # reassigned requests first (priority — they were already queued
        # once); unroutable ones stay for the next step
        if self._reassign:
            stuck = collections.deque()
            while self._reassign:
                req = self._reassign.popleft()
                if not self._route_one(req):
                    stuck.append(req)
            self._reassign = stuck
        # then newly eligible fresh arrivals, in FIFO order — a blocked
        # head blocks the tail (arrival order is part of determinism)
        while self.pending \
                and self.pending[0].arrival_step <= self.cluster_step:
            if not self._route_one(self.pending[0]):
                break
            self.pending.popleft()

        for h in self.replicas:
            h.tick()

        for h in self.replicas:
            for c in h.harvest():
                if c.uid in self.completions:
                    self.duplicate_completions += 1
                    continue
                self.completions[c.uid] = c
                self.owner[c.uid] = h.name

        if self._trace_path is not None:
            self._record_sample()
        self.cluster_step += 1

    def busy(self) -> bool:
        """Any work left anywhere in the cluster?"""
        if self.pending or self._reassign:
            return True
        return any(h.state in (ACTIVE, DRAINING) and h.engine is not None
                   and not h.engine.idle for h in self.replicas)

    def run(self, requests: Sequence[Request],
            max_steps: Optional[int] = None,
            on_step: Optional[Callable[["ReplicaManager"], None]] = None,
            ) -> List[Completion]:
        """Drive the cluster until every request completes.

        ``on_step(manager)`` fires at the START of each cluster step —
        the hook benchmarks/tests use to inject drains, failures, and
        promotions mid-run at a deterministic step."""
        for r in sorted(requests, key=lambda r: (r.arrival_step, r.uid)):
            self.submit(r)
        horizon = max((r.arrival_step for r in requests), default=0)
        per_req = max((h.engine.max_len for h in self.replicas
                       if h.engine is not None), default=64)
        limit = max_steps or (horizon + per_req * (len(requests) + 2))
        while self.busy():
            if self.cluster_step >= limit:
                raise RuntimeError(
                    f"cluster loop exceeded {limit} steps with "
                    f"{len(self.pending)} pending / {len(self._reassign)} "
                    "reassigned requests unplaced — is every replica "
                    "drained or failed?")
            if on_step is not None:
                on_step(self)
            self.step()
        self.close_trace()
        return sorted(self.completions.values(), key=lambda c: c.uid)

    # -- lifecycle -----------------------------------------------------------
    def drain(self, name: str, promote_spare: bool = True) -> List[Request]:
        """Stop admission on ``name``; its queued requests are re-routed
        (priority), in-flight slots finish where they are. Optionally
        promotes a warm spare to replace the lost capacity."""
        h = self.by_name[name]
        evicted = h.begin_drain()
        self._reassign.extend(evicted)
        self.reassigned += len(evicted)
        self.events.append({"step": self.cluster_step, "kind": "drain",
                            "replica": name, "evicted": len(evicted)})
        if promote_spare:
            self.promote_spare()
        return evicted

    def fail(self, name: str, promote_spare: bool = True) -> List[Request]:
        """Simulated replica loss: completions finished before the
        failure are harvested first (they happened), every INCOMPLETE
        request — in-flight and queued — is re-routed with priority."""
        h = self.by_name[name]
        for c in h.harvest():             # keep work that already finished
            if c.uid in self.completions:
                self.duplicate_completions += 1
                continue
            self.completions[c.uid] = c
            self.owner[c.uid] = h.name
        lost = [r for r in h.fail() if r.uid not in self.completions]
        self._reassign.extend(lost)
        self.reassigned += len(lost)
        self.events.append({"step": self.cluster_step, "kind": "fail",
                            "replica": name, "reassigned": len(lost)})
        if promote_spare:
            self.promote_spare()
        return lost

    def promote_spare(self) -> Optional[str]:
        """Promote the first warm spare (list order) to ACTIVE; returns
        its name, or ``None`` when no spare is available."""
        for h in self.replicas:
            if h.state == SPARE:
                h.promote()
                self.events.append({"step": self.cluster_step,
                                    "kind": "promote", "replica": h.name})
                return h.name
        return None

    def restart(self, name: str) -> None:
        """Rebuild a FAILED/DRAINED replica from its factory (latest
        checkpoint) and rejoin it at the current cluster step."""
        self.by_name[name].restart(sync_step=self.cluster_step)
        self.events.append({"step": self.cluster_step, "kind": "restart",
                            "replica": name})

    # -- aggregate stats -----------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Cluster-level latency/TTFT percentiles over ALL completions,
        with throughput over the cluster makespan (slowest replica's
        modeled clock — replicas run concurrently)."""
        comps = sorted(self.completions.values(), key=lambda c: c.uid)
        makespan = max((h.engine.clock for h in self.replicas
                        if h.engine is not None), default=0.0)
        out = latency_percentiles(comps,
                                  total_time_s=makespan or None)
        out["makespan_s"] = float(makespan)
        out["reassigned"] = self.reassigned
        out["duplicates"] = self.duplicate_completions
        return out

    def scores(self) -> Dict[str, float]:
        """Per-replica effective-throughput scores (live replicas)."""
        return {h.name: h.score() for h in self.replicas
                if h.engine is not None}

    # -- cluster trace recording ---------------------------------------------
    def _record_sample(self) -> None:
        """Append one R·W-lane sample: each live replica's current χ feed
        priced through ITS iteration model at full work (the RAW
        heterogeneity, like the fixture generator writes, so replay
        inverts χ exactly); a failed/closed replica records dense lanes
        (χ=1) — it has no feed, and replay should not invent contention.
        """
        per = [h.engine.sim_ranks for h in self.replicas
               if h.engine is not None]
        if not per:
            return
        W = per[0]
        if any(w != W for w in per):
            raise ValueError(
                f"cluster trace needs a uniform TP width; got {per}")
        if self._writer is None:
            ref = next(h.engine for h in self.replicas
                       if h.engine is not None)
            self._writer = TraceWriter(
                self._trace_path, num_ranks=len(self.replicas) * W,
                matmul_time=ref.it_model.matmul_time,
                other_time=ref.it_model.other_time,
                meta={"replicas": len(self.replicas),
                      "ranks_per_replica": W,
                      "source": "repro.cluster.ReplicaManager",
                      "policy": self.router.policy_name})
        ones = np.ones(W)
        rows = []
        for h in self.replicas:
            if h.engine is None:
                rows.append(ones * self._ref_times(ones))
            else:
                chi = h.engine.plane.chi_feed(h.engine.step_count)
                rows.append(h.engine.it_model.times(chi, ones))
        self._writer.append(StepSample(
            step=self.cluster_step,
            rank_times=np.concatenate(rows),
            work_frac=np.ones(len(self.replicas) * W)))

    def _ref_times(self, ones: np.ndarray) -> np.ndarray:
        ref = next(h.engine for h in self.replicas if h.engine is not None)
        return ref.it_model.times(ones, ones)

    def close_trace(self) -> None:
        if self._writer is not None:
            self._writer.close()
            self._writer = None

    def close(self) -> None:
        """Close every live engine (flushes per-replica traces) and the
        cluster trace."""
        for h in self.replicas:
            h.close()
        self.close_trace()
